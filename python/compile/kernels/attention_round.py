"""Layer-1 Pallas kernels for Attention Round (paper Eq. 3-7).

Two kernels:

* ``fakequant``       — forward Eq. (3):  ŵ = s·clip(⌊w/s + α⌉, lo, hi)
* ``attention_grad``  — backward Eq. (6): the Gaussian-attention decay rule
                        dz/dα = 0.5 ± 0.5·erf(α / (√2·τ/s))

Both are elementwise over arbitrarily-shaped weight tensors. The wrapper
flattens + pads to (8, 128) float32 TPU tiles (sublane × lane) and runs a
1-D grid of tiles, so each grid step touches exactly one VMEM-resident
tile — the HBM↔VMEM schedule a TPU would want. On this CPU-only image the
kernels are lowered with ``interpret=True`` (mandatory; Mosaic custom-calls
cannot run on the CPU PJRT plugin), so the tile loop becomes a plain XLA
while-loop with identical numerics.

``attention_quant`` glues them into a ``jax.custom_vjp`` so Layer-2 graphs
differentiate through the quantizer with the paper's update rule instead
of a straight-through estimator.

VMEM/MXU accounting for the real-TPU estimate lives in DESIGN.md §6.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# float32 TPU tile: 8 sublanes x 128 lanes.
SUBLANE = 8
LANE = 128
TILE = SUBLANE * LANE


def _pad2d(flat):
    """Pad a 1-D array to a whole number of (8,128) tiles, reshape 2-D."""
    n = flat.shape[0]
    rows = max((n + LANE - 1) // LANE, SUBLANE)
    rows = ((rows + SUBLANE - 1) // SUBLANE) * SUBLANE
    padded = jnp.zeros((rows * LANE,), flat.dtype).at[:n].set(flat)
    return padded.reshape(rows, LANE), rows


def _elementwise_call(kernel, scalars, tensors, rows):
    """Run an elementwise kernel over a (rows, LANE) grid of (8,128) tiles.

    scalars: tuple of f32[1] arrays, broadcast to every tile.
    tensors: tuple of (rows, LANE) arrays, tiled along rows.
    """
    grid = (rows // SUBLANE,)
    scalar_specs = [pl.BlockSpec((1,), lambda i: (0,)) for _ in scalars]
    tensor_specs = [pl.BlockSpec((SUBLANE, LANE), lambda i: (i, 0)) for _ in tensors]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=scalar_specs + tensor_specs,
        out_specs=pl.BlockSpec((SUBLANE, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANE), jnp.float32),
        interpret=True,
    )(*scalars, *tensors)


# ---------------------------------------------------------------------------
# forward kernel — Eq. (3)
# ---------------------------------------------------------------------------

def _fakequant_kernel(s_ref, lo_ref, hi_ref, w_ref, a_ref, o_ref):
    s = s_ref[0]
    inv = 1.0 / s  # multiply beats divide on both VPU and host
    q = jnp.round(w_ref[...] * inv + a_ref[...])
    o_ref[...] = s * jnp.clip(q, lo_ref[0], hi_ref[0])


def fakequant(w, alpha, s, lo, hi):
    """Eq. (3) over an arbitrary-shape tensor; s/lo/hi runtime scalars."""
    shape = w.shape
    flat, rows = _pad2d(w.reshape(-1))
    aflat, _ = _pad2d(alpha.reshape(-1))
    sc = lambda v: jnp.asarray(v, jnp.float32).reshape((1,))
    out = _elementwise_call(
        _fakequant_kernel, (sc(s), sc(lo), sc(hi)), (flat, aflat), rows
    )
    return out.reshape(-1)[: w.size].reshape(shape)


# ---------------------------------------------------------------------------
# backward kernel — Eq. (6)
# ---------------------------------------------------------------------------

def erf_poly(x):
    """Abramowitz–Stegun 7.1.26 erf (|err| < 1.5e-7), built from primitive
    HLO ops only.

    Two reasons not to use jax.lax.erf: (1) the image's xla_extension
    0.5.1 HLO text parser predates the `erf` opcode jax ≥0.8 emits, so
    artifacts would fail to load; (2) this polynomial is bit-identical to
    the Rust host-side quant::erf, keeping the L1/L3 numerics contract
    exact.
    """
    sign = jnp.sign(x)
    ax = jnp.abs(x)
    t = 1.0 / (1.0 + 0.3275911 * ax)
    y = 1.0 - (
        ((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736)
        * t
        + 0.254829592
    ) * t * jnp.exp(-ax * ax)
    return sign * y


def _attention_grad_kernel(t_ref, g_ref, a_ref, o_ref):
    t = jnp.maximum(t_ref[0], 1e-8)  # τ/s; keep τ=0 finite (Fig. 2 sweep)
    g = g_ref[...]
    e = erf_poly(a_ref[...] * (1.0 / (jnp.sqrt(2.0) * t)))
    dz = jnp.where(g > 0, 0.5 + 0.5 * e, 0.5 - 0.5 * e)
    o_ref[...] = g * dz


def attention_grad(g, alpha, tau_over_s):
    """Eq. (6): dL/dα given upstream dL/dz, elementwise."""
    shape = g.shape
    gflat, rows = _pad2d(g.reshape(-1))
    aflat, _ = _pad2d(alpha.reshape(-1))
    t = jnp.asarray(tau_over_s, jnp.float32).reshape((1,))
    out = _elementwise_call(_attention_grad_kernel, (t,), (gflat, aflat), rows)
    return out.reshape(-1)[: g.size].reshape(shape)


# ---------------------------------------------------------------------------
# the differentiable quantizer
# ---------------------------------------------------------------------------

@jax.custom_vjp
def attention_quant(w, alpha, s, lo, hi, tau_over_s):
    """Differentiable Attention-Round quantizer.

    Forward is Eq. (3); backward routes the output cotangent through the
    Gaussian-attention rule of Eq. (6) into α only (w is the frozen
    pretrained weight — PTQ never updates it).
    """
    return fakequant(w, alpha, s, lo, hi)


def _aq_fwd(w, alpha, s, lo, hi, tau_over_s):
    return fakequant(w, alpha, s, lo, hi), (alpha, s, tau_over_s)


def _aq_bwd(res, g):
    alpha, s, tau_over_s = res
    # dz/dŵ = s on the integer grid; the paper folds the scale into the
    # learning rate, so dL/dα = attention_grad(dL/dz, α). We keep the
    # mathematically consistent s-scaled form.
    da = attention_grad(g * s, alpha, tau_over_s)
    zero = lambda x: jnp.zeros_like(x)
    return (zero(alpha), da, jnp.zeros(()), jnp.zeros(()), jnp.zeros(()),
            jnp.zeros(()))


attention_quant.defvjp(_aq_fwd, _aq_bwd)
