"""Layer-1 Pallas kernel: fake-quantized matmul (the MXU showcase).

The paper's inference hot-spot is quantized GEMM (convs lower to GEMMs).
On a GPU the usual trick is dequantize-on-load into shared memory; the TPU
re-think (DESIGN.md §3) is: fake-quant is fused into the HBM→VMEM tile
load, the MXU consumes the dequantized tile directly, and the grid walks
(M/BM, N/BN) output tiles with the full K panel resident in VMEM.

Block sizing: BM = BN = 128 matches the 128×128 MXU systolic array; the
zoo's K never exceeds 1152, so an (128, K) + (K, 128) + (128, 128) working
set is ≤ 1.3 MiB of f32 VMEM — comfortably inside the ~16 MiB budget, with
double-buffering headroom. interpret=True on this image (see
attention_round.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM = 128
BN = 128


def _qmm_kernel(sx_ref, sw_ref, lox_ref, hix_ref, low_ref, hiw_ref,
                x_ref, w_ref, o_ref):
    sx, sw = sx_ref[0], sw_ref[0]
    xq = sx * jnp.clip(jnp.round(x_ref[...] * (1.0 / sx)), lox_ref[0], hix_ref[0])
    wq = sw * jnp.clip(jnp.round(w_ref[...] * (1.0 / sw)), low_ref[0], hiw_ref[0])
    # f32 accumulate — on TPU this is the MXU path (bf16 inputs would halve
    # VMEM; we keep f32 to match the oracle bit-for-bit).
    o_ref[...] = xq @ wq


def _pad_to(a, rows, cols):
    out = jnp.zeros((rows, cols), a.dtype)
    return out.at[: a.shape[0], : a.shape[1]].set(a)


def qmatmul(x, w, sx, sw, lo_x, hi_x, lo_w, hi_w):
    """(M,K) @ (K,N) with both operands fake-quantized on tile load."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    mp = ((m + BM - 1) // BM) * BM
    np_ = ((n + BN - 1) // BN) * BN
    xpad = _pad_to(x, mp, k)
    wpad = _pad_to(w, k, np_)
    sc = lambda v: jnp.asarray(v, jnp.float32).reshape((1,))
    scalars = [sc(v) for v in (sx, sw, lo_x, hi_x, lo_w, hi_w)]
    grid = (mp // BM, np_ // BN)
    out = pl.pallas_call(
        _qmm_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1,), lambda i, j: (0,)) for _ in scalars]
        + [
            pl.BlockSpec((BM, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, BN), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(*scalars, xpad, wpad)
    return out[:m, :n]
