"""Layer-1 Pallas kernel: tiled Gram matrix G = W·Wᵀ.

Feeds the rate-distortion coding length (paper Eq. 9-12): the bit
allocator needs det(I + n/(mε²)·WWᵀ) per layer, and the Gram product is
the only O(n²m) piece. Tiled (BM, BM) output blocks with the full row
panels VMEM-resident; the Cholesky/log-det tail is tiny and lives in the
Rust linalg substrate (rust/src/linalg/). interpret=True as everywhere.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM = 128


def _gram_kernel(w_ref, wt_ref, o_ref):
    o_ref[...] = w_ref[...] @ wt_ref[...].T


def gram(w):
    """G = w @ w.T for a 2-D (m, n) matrix (m vectors of dim n)."""
    m, n = w.shape
    mp = ((m + BM - 1) // BM) * BM
    wpad = jnp.zeros((mp, n), w.dtype).at[:m, :].set(w)
    out = pl.pallas_call(
        _gram_kernel,
        grid=(mp // BM, mp // BM),
        in_specs=[
            pl.BlockSpec((BM, n), lambda i, j: (i, 0)),
            pl.BlockSpec((BM, n), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((BM, BM), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, mp), jnp.float32),
        interpret=True,
    )(wpad, wpad)
    return out[:m, :m]
