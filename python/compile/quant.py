"""Layer-2 quantization compute graphs — everything the Rust coordinator
executes at runtime, defined once here and AOT-lowered by aot.py.

Per (layer-shape signature):
  * ``attention_calib_step`` — one Adam iteration of the paper's Attention
    Round calibration: reconstruction loss ‖ŵx − wx‖² with the custom-VJP
    quantizer (kernels/attention_round.py), Adam carried in-graph so the
    whole 2k-iteration loop never leaves the device.
  * ``adaround_calib_step`` — the AdaRound baseline (rectified sigmoid
    h(V), annealed-β regularizer) with identical calling shape.
  * ``layer_fwd`` — y = conv(x, w): reference outputs + act capture.

Per model:
  * ``forward``      — logits from (x, w…, b…): evaluation with any weights.
  * ``forward_actq`` — same + per-layer activation fake-quant, scales and
    integer range as runtime inputs (Tables 2/3/5).
  * ``qat_step``     — STE fake-quant SGD step (the Table 3 comparator).

Argument orders are frozen here and recorded in the manifest; the Rust
runtime asserts them at load time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.attention_round import attention_quant
from .layers import ConvSpec, ModelDef, conv_op, forward_infer

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


# ---------------------------------------------------------------------------
# per-layer calibration steps
# ---------------------------------------------------------------------------

def make_attention_calib_step(spec: ConvSpec):
    """(w, x, y_ref, alpha, m, v, t, lr, tau_over_s, s, lo, hi)
       -> (alpha', m', v', loss)"""

    def step(w, x, y_ref, alpha, m, v, t, lr, tau_over_s, s, lo, hi):
        def loss_fn(a):
            w_hat = attention_quant(w, a, s, lo, hi, tau_over_s)
            y = conv_op(x, w_hat, spec)
            return jnp.mean((y - y_ref) ** 2)

        loss, g = jax.value_and_grad(loss_fn)(alpha)
        t1 = t + 1.0
        m = ADAM_B1 * m + (1.0 - ADAM_B1) * g
        v = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
        mhat = m / (1.0 - ADAM_B1**t1)
        vhat = v / (1.0 - ADAM_B2**t1)
        alpha = alpha - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS)
        return alpha, m, v, loss

    return step


def make_attention_calib_scan(spec: ConvSpec, k: int):
    """K fused calibration steps via lax.scan — the device-resident hot
    loop. (w, xs[K], y_refs[K], alpha, m, v, t0, lr, tau_over_s, s, lo, hi)
    -> (alpha', m', v', mean_loss).

    One host↔device round trip per K Adam iterations instead of per
    iteration; EXPERIMENTS.md §Perf measures the difference.
    """
    step = make_attention_calib_step(spec)

    def scan_fn(w, xs, y_refs, alpha, m, v, t0, lr, tau_over_s, s, lo, hi):
        def body(carry, xy):
            alpha, m, v, t = carry
            x, y_ref = xy
            alpha, m, v, loss = step(
                w, x, y_ref, alpha, m, v, t, lr, tau_over_s, s, lo, hi
            )
            return (alpha, m, v, t + 1.0), loss

        (alpha, m, v, _), losses = jax.lax.scan(
            body, (alpha, m, v, t0), (xs, y_refs), length=k
        )
        return alpha, m, v, jnp.mean(losses)

    return scan_fn


def make_adaround_calib_scan(spec: ConvSpec, k: int):
    """K fused AdaRound steps (same shape as the attention scan, plus the
    β/λ regularizer scalars)."""
    step = make_adaround_calib_step(spec)

    def scan_fn(w, xs, y_refs, vv, m, v, t0, lr, beta, lam, s, lo, hi):
        def body(carry, xy):
            vv, m, v, t = carry
            x, y_ref = xy
            vv, m, v, loss = step(w, x, y_ref, vv, m, v, t, lr, beta, lam, s, lo, hi)
            return (vv, m, v, t + 1.0), loss

        (vv, m, v, _), losses = jax.lax.scan(
            body, (vv, m, v, t0), (xs, y_refs), length=k
        )
        return vv, m, v, jnp.mean(losses)

    return scan_fn


def adaround_h(vv):
    """Rectified sigmoid h(V) = clip(sigmoid(V)·(ξ−γ)+γ, 0, 1), ξ=1.1 γ=−0.1."""
    return jnp.clip(jax.nn.sigmoid(vv) * 1.2 - 0.1, 0.0, 1.0)


def make_adaround_calib_step(spec: ConvSpec):
    """(w, x, y_ref, V, m, v, t, lr, beta, lam, s, lo, hi)
       -> (V', m', v', loss)

    AdaRound (Nagel et al. 2020) exactly as §1 of the paper describes it:
    ŵ = s·clip(⌊w/s⌋ + h(V), lo, hi), loss = ‖ŵx − wx‖² + λ·f(V) with
    f(V) = Σ 1 − |2h(V)−1|^β, β annealed by the Rust driver via the runtime
    scalar input.
    """

    def step(w, x, y_ref, vv, m, v, t, lr, beta, lam, s, lo, hi):
        w_floor = jnp.floor(w / s)

        def loss_fn(vv):
            h = adaround_h(vv)
            w_hat = s * jnp.clip(w_floor + h, lo, hi)
            y = conv_op(x, w_hat, spec)
            recon = jnp.mean((y - y_ref) ** 2)
            reg = jnp.sum(1.0 - jnp.abs(2.0 * h - 1.0) ** beta)
            return recon + lam * reg, recon

        (loss, recon), g = jax.value_and_grad(loss_fn, has_aux=True)(vv)
        t1 = t + 1.0
        m = ADAM_B1 * m + (1.0 - ADAM_B1) * g
        v2 = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
        mhat = m / (1.0 - ADAM_B1**t1)
        vhat = v2 / (1.0 - ADAM_B2**t1)
        vv = vv - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS)
        return vv, m, v2, recon

    return step


def make_layer_fwd(spec: ConvSpec):
    """(x, w) -> pre-activation layer output (no bias; it cancels in the
    reconstruction loss)."""

    def fwd(x, w):
        return conv_op(x, w, spec)

    return fwd


# ---------------------------------------------------------------------------
# whole-model executables
# ---------------------------------------------------------------------------

def act_fakequant(x, s, hi):
    """Unsigned activation fake-quant (post-ReLU activations are ≥ 0; the
    stem input is shifted by the observer on the Rust side). hi = 2^b − 1.
    A scale of s with hi huge degenerates to identity — used for the
    'activations FP' rows."""
    return s * jnp.clip(jnp.round(x / s), 0.0, hi)


def make_forward(mdef: ModelDef):
    """(x, w_0..w_k, b_0..b_k) -> logits"""
    k = len(mdef.convs)

    def fwd(*args):
        x = args[0]
        ws = list(args[1 : 1 + k])
        bs = list(args[1 + k : 1 + 2 * k])
        return forward_infer(mdef, ws, bs, x)

    return fwd


def make_forward_actq(mdef: ModelDef):
    """(x, w_0..w_k, b_0..b_k, ascales f32[k], azeros f32[k], ahis f32[k])
    -> logits

    ascales[i] / azeros[i] / ahis[i] are layer i's activation scale,
    zero-shift, and integer max (2^b − 1; per-layer so the first/last
    layers can stay 8-bit per §4.1). Inputs are shifted by the zero-point
    (post-ReLU activations are already ≥ 0; the stem input needs the
    affine shift), quantized on an unsigned grid, and shifted back.
    """
    k = len(mdef.convs)

    def fwd(*args):
        x = args[0]
        ws = list(args[1 : 1 + k])
        bs = list(args[1 + k : 1 + 2 * k])
        ascales = args[1 + 2 * k]
        azeros = args[2 + 2 * k]
        ahis = args[3 + 2 * k]

        def fq(xin, li):
            return act_fakequant(xin - azeros[li], ascales[li], ahis[li]) + azeros[li]

        return forward_infer(mdef, ws, bs, x, act_fq=fq)

    return fwd


def make_collect(mdef: ModelDef):
    """(x, w_0..w_k, b_0..b_k) -> (layer inputs..., logits)

    One forward pass that materializes every quantizable layer's input —
    the calibration activation-capture pass. Works with FP weights (paper
    default) or already-quantized prefixes (config flag on the Rust side).
    """
    k = len(mdef.convs)

    def fwd(*args):
        x = args[0]
        ws = list(args[1 : 1 + k])
        bs = list(args[1 + k : 1 + 2 * k])
        cap = []
        logits = forward_infer(mdef, ws, bs, x, capture=cap)
        return tuple(cap) + (logits,)

    return fwd


# ---------------------------------------------------------------------------
# STE-QAT comparator (Table 3)
# ---------------------------------------------------------------------------

def _ste_fq_weight(w, hi):
    """Symmetric signed STE fake-quant with dynamic max-abs scale."""
    s = jnp.maximum(jnp.max(jnp.abs(w)), 1e-8) / hi
    wq = s * jnp.clip(jnp.round(w / s), -hi - 1.0, hi)
    return w + jax.lax.stop_gradient(wq - w)


def _ste_fq_act(x, hi):
    s = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / hi
    xq = s * jnp.clip(jnp.round(x / s), 0.0, hi)
    return x + jax.lax.stop_gradient(xq - x)


def make_qat_step(mdef: ModelDef):
    """(x, y, w…, b…, mw…, mb…, lr, whi, ahi) -> (w…, b…, mw…, mb…, loss)

    SGD-momentum training with STE fake-quant on weights and activations —
    the budgeted stand-in for the paper's PACT/DSQ/LSQ rows (DESIGN.md §2).
    First and last layers stay 8-bit like every other experiment.
    """
    k = len(mdef.convs)

    def step(*args):
        x, y = args[0], args[1]
        ws = list(args[2 : 2 + k])
        bs = list(args[2 + k : 2 + 2 * k])
        mws = list(args[2 + 2 * k : 2 + 3 * k])
        mbs = list(args[2 + 3 * k : 2 + 4 * k])
        lr, whi, ahi = args[2 + 4 * k], args[3 + 4 * k], args[4 + 4 * k]

        def loss_fn(ws, bs):
            hi8 = 127.0
            wq = [
                _ste_fq_weight(w, hi8 if i in (0, k - 1) else whi)
                for i, w in enumerate(ws)
            ]

            def fq(xin, li):
                return _ste_fq_act(xin, 255.0 if li in (0, k - 1) else ahi)

            logits = forward_infer(mdef, wq, bs, x, act_fq=fq)
            logp = jax.nn.log_softmax(logits)
            return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))

        loss, (gw, gb) = jax.value_and_grad(loss_fn, argnums=(0, 1))(ws, bs)
        mws = [0.9 * m + g for m, g in zip(mws, gw)]
        mbs = [0.9 * m + g for m, g in zip(mbs, gb)]
        ws = [w - lr * m for w, m in zip(ws, mws)]
        bs = [b - lr * m for b, m in zip(bs, mbs)]
        return tuple(ws) + tuple(bs) + tuple(mws) + tuple(mbs) + (loss,)

    return step
