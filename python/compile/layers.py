"""Functional NN layers + a tiny declarative model IR.

Models are described as a flat list of *nodes* operating on a small named
environment (`x`, `skip0`, `skip1`, ...). Quantizable layers (convs and the
final linear) are `ConvSpec`s; everything the rest of the stack needs —
pretraining with BatchNorm, BN folding, activation capture, activation
fake-quant insertion, AOT lowering, and the Rust manifest — is derived
mechanically from this IR. That uniformity is what lets `aot.py` emit
per-layer calibration executables for five architectures without
special-casing any of them.

Conventions: NHWC activations, HWIO conv weights, (in, out) linear weights.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax


@dataclass
class ConvSpec:
    """One quantizable layer (conv / depthwise / group conv / linear)."""

    name: str
    kind: str          # 'conv' | 'dwconv' | 'gconv' | 'linear'
    in_ch: int
    out_ch: int
    ksize: int = 1
    stride: int = 1
    groups: int = 1
    act: str = "none"  # activation applied after conv+BN: none|relu|relu6
    bn: bool = True    # BatchNorm during pretraining (folded at export)

    @property
    def wshape(self) -> tuple:
        if self.kind == "linear":
            return (self.in_ch, self.out_ch)
        if self.kind == "dwconv":
            return (self.ksize, self.ksize, 1, self.out_ch)
        return (self.ksize, self.ksize, self.in_ch // self.groups, self.out_ch)

    @property
    def params(self) -> int:
        n = 1
        for d in self.wshape:
            n *= d
        return n

    @property
    def feature_group_count(self) -> int:
        return self.in_ch if self.kind == "dwconv" else self.groups

    def coding_view(self) -> tuple:
        """(n, m) view for the rate-distortion coding length (paper Eq. 12):
        m output filters, each a vector of dim n = kh*kw*in_ch/groups."""
        if self.kind == "linear":
            return (self.in_ch, self.out_ch)
        kh, kw, ci, co = self.wshape
        return (kh * kw * ci, co)


# ---------------------------------------------------------------------------
# node helpers (the IR)
# ---------------------------------------------------------------------------

def n_conv(spec: ConvSpec, src: str = "x", dst: str = "x") -> dict:
    return {"op": "conv", "spec": spec, "src": src, "dst": dst}


def n_save(dst: str, src: str = "x") -> dict:
    return {"op": "save", "src": src, "dst": dst}


def n_add(other: str, src: str = "x", dst: str = "x", act: str = "none") -> dict:
    return {"op": "add", "src": src, "other": other, "dst": dst, "act": act}


def n_gap() -> dict:  # global average pool NHWC -> NC
    return {"op": "gap"}


@dataclass
class ModelDef:
    name: str
    nodes: list = field(default_factory=list)
    input_hw: int = 32
    num_classes: int = 16

    @property
    def convs(self) -> list:
        return [n["spec"] for n in self.nodes if n["op"] == "conv"]

    def conv_index(self, name: str) -> int:
        for i, s in enumerate(self.convs):
            if s.name == name:
                return i
        raise KeyError(name)


# ---------------------------------------------------------------------------
# primitive ops
# ---------------------------------------------------------------------------

def act_fn(x, act: str):
    if act == "relu":
        return jax.nn.relu(x)
    if act == "relu6":
        return jnp.clip(x, 0.0, 6.0)
    if act == "none":
        return x
    raise ValueError(f"unknown activation {act!r}")


def conv_op(x, w, spec: ConvSpec):
    """Raw convolution / linear matmul (no bias, no activation)."""
    if spec.kind == "linear":
        return x @ w
    pad = (spec.ksize - 1) // 2
    return lax.conv_general_dilated(
        x,
        w,
        window_strides=(spec.stride, spec.stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=spec.feature_group_count,
    )


def batchnorm_train(y, p, momentum=0.9, eps=1e-5):
    """BatchNorm over N,H,W (or N for linear); returns (out, new_running)."""
    axes = tuple(range(y.ndim - 1))
    mean = jnp.mean(y, axis=axes)
    var = jnp.var(y, axis=axes)
    out = (y - mean) / jnp.sqrt(var + eps) * p["gamma"] + p["beta"]
    new_mean = momentum * p["mean"] + (1 - momentum) * mean
    new_var = momentum * p["var"] + (1 - momentum) * var
    return out, {"mean": new_mean, "var": new_var}


def fold_bn(w, p, eps=1e-5):
    """Fold BN (gamma, beta, running mean/var) into conv weight + bias.

    Output-channel is the last weight axis for every kind we support.
    """
    scale = p["gamma"] / np.sqrt(p["var"] + eps)
    w_f = w * scale.reshape((1,) * (w.ndim - 1) + (-1,))
    b_f = p["beta"] - p["mean"] * scale
    return w_f, b_f


# ---------------------------------------------------------------------------
# forward interpreters
# ---------------------------------------------------------------------------

def forward_train(mdef: ModelDef, params: dict, x):
    """Pretraining path: conv -> BN(batch stats) -> act. Returns
    (logits, bn_updates) where bn_updates maps layer name -> new running."""
    env = {"x": x}
    updates = {}
    for node in mdef.nodes:
        if node["op"] == "conv":
            spec = node["spec"]
            p = params[spec.name]
            y = conv_op(env[node["src"]], p["w"], spec)
            if spec.bn:
                y, upd = batchnorm_train(y, p)
                updates[spec.name] = upd
            else:
                y = y + p["b"]
            env[node["dst"]] = act_fn(y, spec.act)
        elif node["op"] == "save":
            env[node["dst"]] = env[node["src"]]
        elif node["op"] == "add":
            env[node["dst"]] = act_fn(env[node["src"]] + env[node["other"]], node["act"])
        elif node["op"] == "gap":
            env["x"] = jnp.mean(env["x"], axis=(1, 2))
        else:
            raise ValueError(node["op"])
    return env["x"], updates


def forward_infer(mdef: ModelDef, weights: list, biases: list, x,
                  act_fq=None, capture=None):
    """Inference path over *folded* per-layer (w, b) lists.

    act_fq: optional callable (x, layer_index) -> x applied to every
        quantizable layer's input (activation fake-quant).
    capture: optional list collecting each quantizable layer's input
        (activation capture for calibration).
    """
    env = {"x": x}
    li = 0
    for node in mdef.nodes:
        if node["op"] == "conv":
            spec = node["spec"]
            xin = env[node["src"]]
            if capture is not None:
                capture.append(xin)
            if act_fq is not None:
                xin = act_fq(xin, li)
            y = conv_op(xin, weights[li], spec) + biases[li]
            env[node["dst"]] = act_fn(y, spec.act)
            li += 1
        elif node["op"] == "save":
            env[node["dst"]] = env[node["src"]]
        elif node["op"] == "add":
            env[node["dst"]] = act_fn(env[node["src"]] + env[node["other"]], node["act"])
        elif node["op"] == "gap":
            env["x"] = jnp.mean(env["x"], axis=(1, 2))
        else:
            raise ValueError(node["op"])
    assert li == len(mdef.convs)
    return env["x"]


def layer_io_shapes(mdef: ModelDef, batch: int) -> list:
    """(in_shape, out_shape_preact) per quantizable layer via abstract eval."""
    shapes = []

    def record(x, li):
        shapes.append(tuple(x.shape))
        return x

    zeros = [jnp.zeros(s.wshape, jnp.float32) for s in mdef.convs]
    zb = [jnp.zeros((s.out_ch,), jnp.float32) for s in mdef.convs]
    x = jnp.zeros((batch, mdef.input_hw, mdef.input_hw, 3), jnp.float32)
    jax.eval_shape(lambda x: forward_infer(mdef, zeros, zb, x, act_fq=record), x)
    out = []
    for spec, in_shape in zip(mdef.convs, shapes):
        y = jax.eval_shape(
            lambda xx, ww, s=spec: conv_op(xx, ww, s),
            jax.ShapeDtypeStruct(in_shape, jnp.float32),
            jax.ShapeDtypeStruct(spec.wshape, jnp.float32),
        )
        out.append((in_shape, tuple(y.shape)))
    return out


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------

def init_params(mdef: ModelDef, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    params = {}
    for spec in mdef.convs:
        fan_in = spec.params // spec.out_ch
        w = rng.normal(0.0, np.sqrt(2.0 / max(fan_in, 1)), spec.wshape)
        p = {"w": jnp.asarray(w, jnp.float32)}
        if spec.bn:
            p["gamma"] = jnp.ones((spec.out_ch,), jnp.float32)
            p["beta"] = jnp.zeros((spec.out_ch,), jnp.float32)
            p["mean"] = jnp.zeros((spec.out_ch,), jnp.float32)
            p["var"] = jnp.ones((spec.out_ch,), jnp.float32)
        else:
            p["b"] = jnp.zeros((spec.out_ch,), jnp.float32)
        params[spec.name] = p
    return params


def fold_model(mdef: ModelDef, params: dict):
    """Fold BN into per-layer (weights, biases) lists, ordered like convs."""
    ws, bs = [], []
    for spec in mdef.convs:
        p = params[spec.name]
        w = np.asarray(p["w"])
        if spec.bn:
            w_f, b_f = fold_bn(
                w,
                {k: np.asarray(p[k]) for k in ("gamma", "beta", "mean", "var")},
            )
        else:
            w_f, b_f = w, np.asarray(p["b"])
        ws.append(np.asarray(w_f, np.float32))
        bs.append(np.asarray(b_f, np.float32))
    return ws, bs
