"""AOT build driver: dataset → pretraining → HLO lowering → manifest.

Runs once at `make artifacts`; Python never appears on the request path
afterwards. Every executable the Rust coordinator needs is lowered here to
**HLO text** (xla_extension 0.5.1 rejects jax≥0.5 serialized protos — the
text parser reassigns the 64-bit instruction ids, see
/opt/xla-example/README.md) and indexed in artifacts/manifest.json.

Layer-level executables are deduplicated by shape signature: two layers
with identical (kind, kernel, stride, groups, weight shape, input shape)
share one artifact. This collapses ~100 zoo layers to a few dozen HLO
modules and keeps both lowering time and Rust compile time bounded.

Layout:
  artifacts/
    data/{train,calib,eval}_{x,y}.npy
    weights/<model>/<idx>_<name>.{w,b}.npy
    hlo/<sig or model>.hlo.txt
    manifest.json
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as dataset
from . import quant
from .layers import ModelDef, layer_io_shapes
from .models import ZOO, build
from .train import train_model

CALIB_BATCH = 32
EVAL_BATCH = 128
QAT_BATCH = 64
QAT_MODELS = ("resnet18t", "mobilenetv2t")
# K-step fused calibration (lax.scan) — one PJRT dispatch per K Adam
# iterations. 8 keeps the largest per-sig (xs, y_refs) stack < 40 MB.
SCAN_K = 8

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_to_file(fn, specs, path: str, force: bool = False) -> None:
    if os.path.exists(path) and not force:
        return
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)


def sds(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


SCALAR = sds(())


def layer_sig(spec, in_shape) -> str:
    w = "x".join(map(str, spec.wshape))
    i = "x".join(map(str, in_shape))
    return f"{spec.kind}_k{spec.ksize}_s{spec.stride}_g{spec.feature_group_count}_w{w}_i{i}"


# ---------------------------------------------------------------------------

def export_dataset(out: str) -> dict:
    ddir = os.path.join(out, "data")
    for split in dataset.SPLITS:
        dataset.load_or_make(ddir, split)
    return {
        "dir": "data",
        "num_classes": dataset.NUM_CLASSES,
        "image_hw": dataset.IMG,
        "channels": dataset.CHANNELS,
        "splits": {k: {"n": n, "seed": s} for k, (n, s) in dataset.SPLITS.items()},
        "calib_batch": CALIB_BATCH,
        "eval_batch": EVAL_BATCH,
        "qat_batch": QAT_BATCH,
    }


def train_or_load(name: str, out: str):
    """Pretrain (or reuse cached weights) and export per-layer npy files."""
    wdir = os.path.join(out, "weights", name)
    meta_path = os.path.join(wdir, "meta.json")
    mdef = build(name)
    if os.path.exists(meta_path):
        meta = json.load(open(meta_path))
        ws = [np.load(os.path.join(wdir, f)) for f in meta["w_files"]]
        bs = [np.load(os.path.join(wdir, f)) for f in meta["b_files"]]
        return mdef, ws, bs, meta["fp_acc"]
    mdef, ws, bs, acc = train_model(name, os.path.join(out, "data"))
    os.makedirs(wdir, exist_ok=True)
    w_files, b_files = [], []
    for i, (spec, w, b) in enumerate(zip(mdef.convs, ws, bs)):
        safe = spec.name.replace(".", "_")
        wf, bf = f"{i:02d}_{safe}.w.npy", f"{i:02d}_{safe}.b.npy"
        np.save(os.path.join(wdir, wf), w)
        np.save(os.path.join(wdir, bf), b)
        w_files.append(wf)
        b_files.append(bf)
    json.dump(
        {"fp_acc": acc, "w_files": w_files, "b_files": b_files},
        open(meta_path, "w"),
    )
    return mdef, ws, bs, acc


def lower_layer_artifacts(mdef: ModelDef, out: str, lowered_sigs: set) -> list:
    """Per-layer calib/adaround/layer_fwd executables, dedup by signature."""
    hdir = os.path.join(out, "hlo")
    io = layer_io_shapes(mdef, CALIB_BATCH)
    entries = []
    for li, (spec, (in_shape, out_shape)) in enumerate(zip(mdef.convs, io)):
        sig = layer_sig(spec, in_shape)
        if sig not in lowered_sigs:
            lowered_sigs.add(sig)
            w, xs, ys = sds(spec.wshape), sds(in_shape), sds(out_shape)
            lower_to_file(
                quant.make_attention_calib_step(spec),
                # (w, x, y_ref, alpha, m, v, t, lr, tau_over_s, s, lo, hi)
                (w, xs, ys, w, w, w) + (SCALAR,) * 6,
                os.path.join(hdir, f"calib_{sig}.hlo.txt"),
            )
            lower_to_file(
                quant.make_adaround_calib_step(spec),
                # (w, x, y_ref, V, m, v, t, lr, beta, lam, s, lo, hi)
                (w, xs, ys, w, w, w) + (SCALAR,) * 7,
                os.path.join(hdir, f"adaround_{sig}.hlo.txt"),
            )
            lower_to_file(
                quant.make_layer_fwd(spec),
                (xs, w),
                os.path.join(hdir, f"layerfwd_{sig}.hlo.txt"),
            )
            xss = sds((SCAN_K,) + tuple(in_shape))
            yss = sds((SCAN_K,) + tuple(out_shape))
            lower_to_file(
                quant.make_attention_calib_scan(spec, SCAN_K),
                # (w, xs, y_refs, alpha, m, v, t0, lr, tau_over_s, s, lo, hi)
                (w, xss, yss, w, w, w) + (SCALAR,) * 6,
                os.path.join(hdir, f"calibscan_{sig}.hlo.txt"),
            )
            lower_to_file(
                quant.make_adaround_calib_scan(spec, SCAN_K),
                (w, xss, yss, w, w, w) + (SCALAR,) * 7,
                os.path.join(hdir, f"adascan_{sig}.hlo.txt"),
            )
        entries.append(
            {
                "index": li,
                "name": spec.name,
                "kind": spec.kind,
                "ksize": spec.ksize,
                "stride": spec.stride,
                "groups": spec.feature_group_count,
                "act": spec.act,
                "wshape": list(spec.wshape),
                "params": spec.params,
                "coding_n": spec.coding_view()[0],
                "coding_m": spec.coding_view()[1],
                "in_shape": list(in_shape),
                "out_shape": list(out_shape),
                "pinned_8bit": li in (0, len(mdef.convs) - 1),
                "downsample": spec.name.endswith(".down"),
                "sig": sig,
                "calib_step": f"hlo/calib_{sig}.hlo.txt",
                "adaround_step": f"hlo/adaround_{sig}.hlo.txt",
                "layer_fwd": f"hlo/layerfwd_{sig}.hlo.txt",
                "calib_scan": f"hlo/calibscan_{sig}.hlo.txt",
                "adaround_scan": f"hlo/adascan_{sig}.hlo.txt",
            }
        )
    return entries


def lower_model_artifacts(mdef: ModelDef, out: str) -> dict:
    hdir = os.path.join(out, "hlo")
    k = len(mdef.convs)
    wspecs = [sds(s.wshape) for s in mdef.convs]
    bspecs = [sds((s.out_ch,)) for s in mdef.convs]
    x_eval = sds((EVAL_BATCH, mdef.input_hw, mdef.input_hw, 3))
    x_calib = sds((CALIB_BATCH, mdef.input_hw, mdef.input_hw, 3))

    paths = {
        "forward": f"hlo/forward_{mdef.name}.hlo.txt",
        "forward_actq": f"hlo/forward_actq_{mdef.name}.hlo.txt",
        "collect": f"hlo/collect_{mdef.name}.hlo.txt",
    }
    lower_to_file(
        quant.make_forward(mdef),
        (x_eval, *wspecs, *bspecs),
        os.path.join(out, paths["forward"]),
    )
    lower_to_file(
        quant.make_forward_actq(mdef),
        (x_eval, *wspecs, *bspecs, sds((k,)), sds((k,)), sds((k,))),
        os.path.join(out, paths["forward_actq"]),
    )
    lower_to_file(
        quant.make_collect(mdef),
        (x_calib, *wspecs, *bspecs),
        os.path.join(out, paths["collect"]),
    )
    if mdef.name in QAT_MODELS:
        paths["qat_step"] = f"hlo/qat_{mdef.name}.hlo.txt"
        xq = sds((QAT_BATCH, mdef.input_hw, mdef.input_hw, 3))
        yq = sds((QAT_BATCH,), jnp.int32)
        lower_to_file(
            quant.make_qat_step(mdef),
            (xq, yq, *wspecs, *bspecs, *wspecs, *bspecs) + (SCALAR,) * 3,
            os.path.join(out, paths["qat_step"]),
        )
    return paths


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default=",".join(ZOO))
    args = ap.parse_args()
    out = args.out
    os.makedirs(os.path.join(out, "hlo"), exist_ok=True)

    t0 = time.time()
    ds_meta = export_dataset(out)
    print(f"[aot] dataset ready ({time.time() - t0:.1f}s)", flush=True)

    manifest = {
        "format_version": 1,
        "paper": "Attention Round for Post-Training Quantization (Diao et al., 2022)",
        "dataset": ds_meta,
        "scan_k": SCAN_K,
        "arg_conventions": {
            "calib_step": "(w, x, y_ref, alpha, m, v, t, lr, tau_over_s, s, lo, hi) -> (alpha, m, v, loss)",
            "calib_scan": "(w, xs[K], y_refs[K], alpha, m, v, t0, lr, tau_over_s, s, lo, hi) -> (alpha, m, v, mean_loss)",
            "adaround_scan": "(w, xs[K], y_refs[K], V, m, v, t0, lr, beta, lam, s, lo, hi) -> (V, m, v, mean_recon)",
            "adaround_step": "(w, x, y_ref, V, m, v, t, lr, beta, lam, s, lo, hi) -> (V, m, v, recon_loss)",
            "layer_fwd": "(x, w) -> y_preact",
            "forward": "(x, w..., b...) -> logits",
            "forward_actq": "(x, w..., b..., ascales[k], azeros[k], ahis[k]) -> logits",
            "collect": "(x, w..., b...) -> (layer_inputs..., logits)",
            "qat_step": "(x, y, w..., b..., mw..., mb..., lr, whi, ahi) -> (w..., b..., mw..., mb..., loss)",
        },
        "models": {},
    }

    lowered_sigs: set = set()
    for name in args.models.split(","):
        t1 = time.time()
        mdef, ws, bs, acc = train_or_load(name, out)
        print(f"[aot] {name}: fp_acc={acc:.4f} ({time.time() - t1:.1f}s)", flush=True)
        t1 = time.time()
        layers = lower_layer_artifacts(mdef, out, lowered_sigs)
        paths = lower_model_artifacts(mdef, out)
        print(f"[aot] {name}: lowered {len(layers)} layers ({time.time() - t1:.1f}s)",
              flush=True)
        manifest["models"][name] = {
            "fp_acc": acc,
            "num_layers": len(layers),
            "weights_dir": f"weights/{name}",
            "w_files": [f"weights/{name}/{f}" for f in
                        json.load(open(os.path.join(out, "weights", name, "meta.json")))["w_files"]],
            "b_files": [f"weights/{name}/{f}" for f in
                        json.load(open(os.path.join(out, "weights", name, "meta.json")))["b_files"]],
            "layers": layers,
            **paths,
        }

    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] manifest written; total {time.time() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
