"""Synthetic image dataset — the ImageNet stand-in (DESIGN.md §2).

Post-training quantization calibrates on *in-distribution activations*;
the semantic content of the images is irrelevant to the paper's claims
(all methods see identical data, so the relative ordering of rounding
functions is preserved). We therefore generate a deterministic procedural
dataset: 16 classes of oriented sinusoidal gratings ("gabor" textures)
with class-specific frequency / orientation / color bias, randomized
phase, contrast, spatial jitter and additive Gaussian noise. Difficulty
is tuned so the FP models land around 85-95% top-1 — high enough that
quantization damage is measurable, low enough that the task is non-trivial.

Everything is keyed off a single integer seed; the same generator is
ported to Rust (rust/src/data/synth.rs) for bench workload generation,
and cross-checked against these arrays in tests.
"""

from __future__ import annotations

import numpy as np

NUM_CLASSES = 16
IMG = 32  # height == width
CHANNELS = 3

# Per-class texture parameters, fixed by construction (not by RNG) so the
# Rust port can reproduce them exactly.
def class_params(c: int) -> dict:
    """Deterministic texture parameters for class c."""
    freq = 1.5 + 0.45 * (c % 8)             # cycles across the image
    theta = (c * 137.508) % 180.0           # golden-angle orientations
    color_phase = (c * 2.399) % (2 * np.pi) # color rotation
    return {
        "freq": freq,
        "theta_deg": theta,
        "color": np.array(
            [
                0.6 + 0.4 * np.sin(color_phase),
                0.6 + 0.4 * np.sin(color_phase + 2.094),
                0.6 + 0.4 * np.sin(color_phase + 4.189),
            ],
            dtype=np.float64,
        ),
        "second_freq": 2.2 + 0.3 * ((c // 8) % 2),
    }


def generate_split(n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """Generate (images NHWC float32, labels int32).

    Images are roughly zero-mean unit-ish scale (normalized like standard
    ImageNet preprocessing), which keeps conv activations in a sane range.
    """
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, NUM_CLASSES, size=n).astype(np.int32)
    xs = np.empty((n, IMG, IMG, CHANNELS), dtype=np.float32)

    yy, xx = np.meshgrid(np.arange(IMG), np.arange(IMG), indexing="ij")
    yy = yy.astype(np.float64) / IMG
    xx = xx.astype(np.float64) / IMG

    for i in range(n):
        c = int(labels[i])
        p = class_params(c)
        th = np.deg2rad(p["theta_deg"] + rng.normal(0.0, 9.0))
        phase = rng.uniform(0.0, 2 * np.pi)
        contrast = rng.uniform(0.45, 1.2)
        # primary grating
        u = np.cos(th) * xx + np.sin(th) * yy
        g = np.sin(2 * np.pi * p["freq"] * u + phase)
        # secondary orthogonal grating (weaker) -> texture, not pure stripes
        v = -np.sin(th) * xx + np.cos(th) * yy
        g2 = np.sin(2 * np.pi * p["second_freq"] * v + phase * 0.5)
        tex = contrast * (0.8 * g + 0.35 * g2)
        img = tex[:, :, None] * p["color"][None, None, :]
        img = img + rng.normal(0.0, 1.0, size=img.shape)  # heavy noise floor
        # random occlusion patch (cutout) — forces non-local features
        ph, pw = rng.integers(8, 17), rng.integers(8, 17)
        py, px = rng.integers(0, IMG - ph + 1), rng.integers(0, IMG - pw + 1)
        img[py : py + ph, px : px + pw, :] = 0.0
        xs[i] = img.astype(np.float32)
    return xs, labels


# Canonical splits (seeds are part of the repo's reproducibility contract).
SPLITS = {
    "train": (8192, 1000),
    "calib": (1024, 2000),   # the paper's 1,024-image calibration set
    "eval": (2048, 3000),
}


def load_or_make(out_dir, split: str):
    """Generate a split lazily and cache it under out_dir as .npy."""
    import os

    n, seed = SPLITS[split]
    xp = os.path.join(out_dir, f"{split}_x.npy")
    yp = os.path.join(out_dir, f"{split}_y.npy")
    if os.path.exists(xp) and os.path.exists(yp):
        return np.load(xp), np.load(yp)
    os.makedirs(out_dir, exist_ok=True)
    xs, ys = generate_split(n, seed)
    np.save(xp, xs)
    np.save(yp, ys)
    return xs, ys
