"""Pretraining — produces the FP32 checkpoints that PTQ starts from.

This is the build-time substitute for "download a pretrained torchvision
model" (DESIGN.md §2): each zoo model is trained to convergence on the
synthetic dataset with Adam + cosine LR, BatchNorm in train mode, then the
BN parameters are folded into conv weight+bias pairs and exported as
per-layer .npy files for the Rust coordinator.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as dataset
from .layers import ModelDef, fold_model, forward_infer, forward_train, init_params
from .models import build


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def make_step(mdef: ModelDef, base_lr: float, total_steps: int):
    """One jitted Adam training step over the params pytree."""

    def loss_fn(trainable, frozen, x, y):
        params = merge(mdef, trainable, frozen)
        logits, updates = forward_train(mdef, params, x)
        return cross_entropy(logits, y), updates

    def step(trainable, frozen, opt, x, y, t):
        (loss, updates), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            trainable, frozen, x, y
        )
        lr = base_lr * 0.5 * (1 + jnp.cos(jnp.pi * t / total_steps))
        m, v = opt
        m = jax.tree.map(lambda a, g: 0.9 * a + 0.1 * g, m, grads)
        v = jax.tree.map(lambda a, g: 0.999 * a + 0.001 * g * g, v, grads)
        tt = t + 1.0
        new_trainable = jax.tree.map(
            lambda p, mm, vv: p
            - lr * (mm / (1 - 0.9**tt)) / (jnp.sqrt(vv / (1 - 0.999**tt)) + 1e-8),
            trainable, m, v,
        )
        # fold BN running-stat updates back into the frozen side
        new_frozen = dict(frozen)
        for name, upd in updates.items():
            nf = dict(new_frozen[name])
            nf.update(upd)
            new_frozen[name] = nf
        return new_trainable, new_frozen, (m, v), loss

    return jax.jit(step)


def split_params(mdef: ModelDef, params: dict):
    """(trainable, frozen): running BN stats are not differentiated."""
    trainable, frozen = {}, {}
    for name, p in params.items():
        t = {k: v for k, v in p.items() if k in ("w", "b", "gamma", "beta")}
        f = {k: v for k, v in p.items() if k in ("mean", "var")}
        trainable[name] = t
        frozen[name] = f
    return trainable, frozen


def merge(mdef: ModelDef, trainable: dict, frozen: dict) -> dict:
    return {
        name: {**trainable[name], **frozen.get(name, {})} for name in trainable
    }


def evaluate_fp(mdef: ModelDef, ws, bs, xs, ys, batch=128) -> float:
    fwd = jax.jit(lambda x: forward_infer(mdef, [jnp.asarray(w) for w in ws],
                                          [jnp.asarray(b) for b in bs], x))
    correct = 0
    n = (len(xs) // batch) * batch
    for i in range(0, n, batch):
        logits = fwd(jnp.asarray(xs[i : i + batch]))
        correct += int(jnp.sum(jnp.argmax(logits, -1) == jnp.asarray(ys[i : i + batch])))
    return correct / n


def train_model(name: str, data_dir: str, steps: int | None = None, batch: int = 64,
                lr: float = 2e-3, seed: int = 0, verbose: bool = True):
    """steps default: AR_TRAIN_STEPS env (350) — the build knob the
    Makefile exposes for constrained CI machines."""
    import os

    if steps is None:
        steps = int(os.environ.get("AR_TRAIN_STEPS", "350"))
    """Train one zoo model; returns (mdef, folded_ws, folded_bs, fp_acc)."""
    mdef = build(name)
    xs, ys = dataset.load_or_make(data_dir, "train")
    params = init_params(mdef, seed=seed)
    trainable, frozen = split_params(mdef, params)
    opt = (
        jax.tree.map(jnp.zeros_like, trainable),
        jax.tree.map(jnp.zeros_like, trainable),
    )
    step = make_step(mdef, lr, steps)
    rng = np.random.default_rng(seed + 7)
    t0 = time.time()
    loss = None
    for t in range(steps):
        idx = rng.integers(0, len(xs), size=batch)
        trainable, frozen, opt, loss = step(
            trainable, frozen, opt,
            jnp.asarray(xs[idx]), jnp.asarray(ys[idx]), float(t),
        )
        if verbose and (t % 100 == 0 or t == steps - 1):
            print(f"[{name}] step {t:4d} loss {float(loss):.4f} "
                  f"({time.time() - t0:.1f}s)", flush=True)
    params = merge(mdef, trainable, frozen)
    ws, bs = fold_model(mdef, params)
    ex, ey = dataset.load_or_make(data_dir, "eval")
    acc = evaluate_fp(mdef, ws, bs, ex, ey)
    if verbose:
        print(f"[{name}] FP32 top-1 {acc * 100:.2f}%  ({time.time() - t0:.1f}s)",
              flush=True)
    return mdef, ws, bs, acc
