"""The model zoo — tiny counterparts of the paper's five architectures.

Each model exercises the conv variant the paper chose it for:

* ``resnet18t``    — ordinary 3x3 convs, basic residual blocks
* ``resnet50t``    — 1x1/3x3/1x1 bottleneck residual blocks
* ``mobilenetv2t`` — depthwise-separable inverted residual blocks (ReLU6)
* ``regnett``      — group-conv X-blocks (RegNetX style)
* ``mnasnett``     — NAS-style mix of sepconv + MBConv with k=5 kernels

The paper's structural features that matter for its experiments are kept:
BatchNorm after every conv (folded before quantization, §4.1), a conv stem,
residual topology with 1x1 downsample branches (the layers Figure 3-5 show
getting the narrowest bits), and a final linear classifier (first + last
layers are pinned to 8-bit, §4.1).
"""

from __future__ import annotations

from .layers import ConvSpec, ModelDef, n_add, n_conv, n_gap, n_save


def _c(name, kind, ci, co, k=1, s=1, g=1, act="none", bn=True) -> ConvSpec:
    return ConvSpec(name=name, kind=kind, in_ch=ci, out_ch=co, ksize=k,
                    stride=s, groups=g, act=act, bn=bn)


# ---------------------------------------------------------------------------
# ResNet-18 (basic blocks)
# ---------------------------------------------------------------------------

def resnet18t() -> ModelDef:
    m = ModelDef("resnet18t")
    nodes = m.nodes
    nodes.append(n_conv(_c("stem", "conv", 3, 16, k=3, s=1, act="relu")))
    ci = 16
    widths = [16, 32, 64, 128]
    for si, co in enumerate(widths):
        for bi in range(2):
            s = 2 if (si > 0 and bi == 0) else 1
            pre = f"s{si}b{bi}"
            nodes.append(n_save("skip"))
            nodes.append(n_conv(_c(f"{pre}.conv1", "conv", ci, co, k=3, s=s, act="relu")))
            nodes.append(n_conv(_c(f"{pre}.conv2", "conv", co, co, k=3, s=1)))
            if s != 1 or ci != co:
                nodes.append(n_conv(_c(f"{pre}.down", "conv", ci, co, k=1, s=s),
                                    src="skip", dst="skip"))
            nodes.append(n_add("skip", act="relu"))
            ci = co
    nodes.append(n_gap())
    nodes.append(n_conv(_c("fc", "linear", ci, m.num_classes, bn=False)))
    return m


# ---------------------------------------------------------------------------
# ResNet-50 (bottleneck blocks, expansion 2)
# ---------------------------------------------------------------------------

def resnet50t() -> ModelDef:
    m = ModelDef("resnet50t")
    nodes = m.nodes
    nodes.append(n_conv(_c("stem", "conv", 3, 16, k=3, s=1, act="relu")))
    ci = 16
    exp = 2
    cfg = [(16, 1, 1), (32, 2, 2), (64, 2, 2), (128, 1, 2)]  # (mid, blocks, stride)
    for si, (mid, blocks, stride) in enumerate(cfg):
        co = mid * exp
        for bi in range(blocks):
            s = stride if bi == 0 else 1
            pre = f"s{si}b{bi}"
            nodes.append(n_save("skip"))
            nodes.append(n_conv(_c(f"{pre}.conv1", "conv", ci, mid, k=1, act="relu")))
            nodes.append(n_conv(_c(f"{pre}.conv2", "conv", mid, mid, k=3, s=s, act="relu")))
            nodes.append(n_conv(_c(f"{pre}.conv3", "conv", mid, co, k=1)))
            if s != 1 or ci != co:
                nodes.append(n_conv(_c(f"{pre}.down", "conv", ci, co, k=1, s=s),
                                    src="skip", dst="skip"))
            nodes.append(n_add("skip", act="relu"))
            ci = co
    nodes.append(n_gap())
    nodes.append(n_conv(_c("fc", "linear", ci, m.num_classes, bn=False)))
    return m


# ---------------------------------------------------------------------------
# MobileNetV2 (inverted residuals, ReLU6)
# ---------------------------------------------------------------------------

def mobilenetv2t() -> ModelDef:
    m = ModelDef("mobilenetv2t")
    nodes = m.nodes
    nodes.append(n_conv(_c("stem", "conv", 3, 16, k=3, s=1, act="relu6")))
    ci = 16
    # (out, stride, expansion)
    cfg = [(16, 1, 1), (24, 2, 4), (24, 1, 4), (32, 2, 4), (32, 1, 4),
           (64, 2, 4), (64, 1, 4)]
    for bi, (co, s, e) in enumerate(cfg):
        pre = f"b{bi}"
        mid = ci * e
        residual = (s == 1 and ci == co)
        if residual:
            nodes.append(n_save("skip"))
        if e != 1:
            nodes.append(n_conv(_c(f"{pre}.expand", "conv", ci, mid, k=1, act="relu6")))
        nodes.append(n_conv(_c(f"{pre}.dw", "dwconv", mid, mid, k=3, s=s, act="relu6")))
        nodes.append(n_conv(_c(f"{pre}.project", "conv", mid, co, k=1)))
        if residual:
            nodes.append(n_add("skip"))
        ci = co
    nodes.append(n_conv(_c("head", "conv", ci, 128, k=1, act="relu6")))
    nodes.append(n_gap())
    nodes.append(n_conv(_c("fc", "linear", 128, m.num_classes, bn=False)))
    return m


# ---------------------------------------------------------------------------
# RegNetX-style (group-conv X blocks)
# ---------------------------------------------------------------------------

def regnett() -> ModelDef:
    m = ModelDef("regnett")
    nodes = m.nodes
    nodes.append(n_conv(_c("stem", "conv", 3, 16, k=3, s=1, act="relu")))
    ci = 16
    cfg = [(32, 1, 1), (64, 2, 2), (128, 2, 2)]  # (width, blocks, stride); g=8
    for si, (co, blocks, stride) in enumerate(cfg):
        for bi in range(blocks):
            s = stride if bi == 0 else 1
            pre = f"s{si}b{bi}"
            nodes.append(n_save("skip"))
            nodes.append(n_conv(_c(f"{pre}.conv1", "conv", ci, co, k=1, act="relu")))
            nodes.append(n_conv(_c(f"{pre}.conv2", "gconv", co, co, k=3, s=s, g=8, act="relu")))
            nodes.append(n_conv(_c(f"{pre}.conv3", "conv", co, co, k=1)))
            if s != 1 or ci != co:
                nodes.append(n_conv(_c(f"{pre}.down", "conv", ci, co, k=1, s=s),
                                    src="skip", dst="skip"))
            nodes.append(n_add("skip", act="relu"))
            ci = co
    nodes.append(n_gap())
    nodes.append(n_conv(_c("fc", "linear", ci, m.num_classes, bn=False)))
    return m


# ---------------------------------------------------------------------------
# MnasNet-style (NAS mix: sepconv + MBConv k3/k5)
# ---------------------------------------------------------------------------

def mnasnett() -> ModelDef:
    m = ModelDef("mnasnett")
    nodes = m.nodes
    nodes.append(n_conv(_c("stem", "conv", 3, 16, k=3, s=1, act="relu6")))
    # sepconv block
    nodes.append(n_conv(_c("sep.dw", "dwconv", 16, 16, k=3, act="relu6")))
    nodes.append(n_conv(_c("sep.pw", "conv", 16, 16, k=1)))
    ci = 16
    # (out, stride, expansion, kernel)
    cfg = [(24, 2, 3, 3), (24, 1, 3, 3), (40, 2, 3, 5), (40, 1, 3, 5),
           (80, 2, 6, 5), (96, 1, 6, 3)]
    for bi, (co, s, e, k) in enumerate(cfg):
        pre = f"mb{bi}"
        mid = ci * e
        residual = (s == 1 and ci == co)
        if residual:
            nodes.append(n_save("skip"))
        nodes.append(n_conv(_c(f"{pre}.expand", "conv", ci, mid, k=1, act="relu6")))
        nodes.append(n_conv(_c(f"{pre}.dw", "dwconv", mid, mid, k=k, s=s, act="relu6")))
        nodes.append(n_conv(_c(f"{pre}.project", "conv", mid, co, k=1)))
        if residual:
            nodes.append(n_add("skip"))
        ci = co
    nodes.append(n_gap())
    nodes.append(n_conv(_c("fc", "linear", ci, m.num_classes, bn=False)))
    return m


ZOO = {
    "resnet18t": resnet18t,
    "resnet50t": resnet50t,
    "mobilenetv2t": mobilenetv2t,
    "regnett": regnett,
    "mnasnett": mnasnett,
}


def build(name: str) -> ModelDef:
    return ZOO[name]()
