"""Dataset generator contracts (mirrored by rust/src/data/synth.rs tests)."""

import numpy as np

from compile import data


def test_determinism():
    a, la = data.generate_split(16, 42)
    b, lb = data.generate_split(16, 42)
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(la, lb)
    c, _ = data.generate_split(16, 43)
    assert not np.array_equal(a, c)


def test_shapes_and_dtypes():
    xs, ys = data.generate_split(8, 0)
    assert xs.shape == (8, 32, 32, 3)
    assert xs.dtype == np.float32
    assert ys.dtype == np.int32
    assert ys.min() >= 0 and ys.max() < data.NUM_CLASSES


def test_moments():
    xs, _ = data.generate_split(64, 1)
    assert abs(float(xs.mean())) < 0.1
    assert 0.5 < float(xs.var()) < 2.0


def test_class_params_stable():
    """The closed-form class parameters are a cross-language contract with
    rust/src/data/synth.rs — pin a few values."""
    p3 = data.class_params(3)
    assert abs(p3["freq"] - 2.85) < 1e-9
    assert abs(p3["theta_deg"] - (3 * 137.508) % 180.0) < 1e-9
    p8 = data.class_params(8)
    assert abs(p8["second_freq"] - 2.5) < 1e-9


def test_cutout_present():
    xs, _ = data.generate_split(4, 7)
    for img in xs:
        assert (img == 0.0).sum() >= 8 * 8 * 3


def test_splits_config():
    assert data.SPLITS["calib"][0] == 1024  # the paper's calibration budget
    assert data.SPLITS["train"][0] >= 4 * data.SPLITS["calib"][0]
