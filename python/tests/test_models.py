"""L2 model-zoo contracts: shapes, BN folding, IR consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.layers import (
    fold_model,
    forward_infer,
    forward_train,
    init_params,
    layer_io_shapes,
)
from compile.models import ZOO, build


@pytest.mark.parametrize("name", list(ZOO))
def test_forward_shapes(name):
    mdef = build(name)
    params = init_params(mdef, seed=0)
    ws, bs = fold_model(mdef, params)
    x = jnp.zeros((2, 32, 32, 3), jnp.float32)
    logits = forward_infer(mdef, [jnp.asarray(w) for w in ws],
                           [jnp.asarray(b) for b in bs], x)
    assert logits.shape == (2, 16)


@pytest.mark.parametrize("name", list(ZOO))
def test_layer_io_shapes_consistent(name):
    mdef = build(name)
    io = layer_io_shapes(mdef, 4)
    assert len(io) == len(mdef.convs)
    for spec, (in_shape, out_shape) in zip(mdef.convs, io):
        assert in_shape[0] == 4 and out_shape[0] == 4
        assert out_shape[-1] == spec.out_ch
        if spec.kind != "linear":
            assert in_shape[-1] == spec.in_ch


@pytest.mark.parametrize("name", list(ZOO))
def test_first_last_layers(name):
    mdef = build(name)
    convs = mdef.convs
    assert convs[0].name == "stem"
    assert convs[-1].kind == "linear"
    assert not convs[-1].bn  # classifier has a real bias


def test_bn_folding_matches_eval_mode():
    """After folding, inference must equal conv+BN(running stats)+act."""
    mdef = build("resnet18t")
    params = init_params(mdef, seed=3)
    # push the BN stats away from init so folding is non-trivial
    rng = np.random.default_rng(0)
    for p in params.values():
        if "mean" in p:
            p["mean"] = jnp.asarray(rng.normal(0, 0.2, p["mean"].shape), jnp.float32)
            p["var"] = jnp.asarray(rng.uniform(0.5, 2.0, p["var"].shape), jnp.float32)
            p["gamma"] = jnp.asarray(rng.uniform(0.5, 1.5, p["gamma"].shape), jnp.float32)
            p["beta"] = jnp.asarray(rng.normal(0, 0.1, p["beta"].shape), jnp.float32)
    ws, bs = fold_model(mdef, params)
    x = jnp.asarray(rng.normal(0, 1, (2, 32, 32, 3)), jnp.float32)
    folded = forward_infer(mdef, [jnp.asarray(w) for w in ws],
                           [jnp.asarray(b) for b in bs], x)

    # manual eval-mode BN reference via forward_train with batch stats
    # replaced by running stats: emulate by scaling inputs through the
    # folded math layer-by-layer — instead compare against a direct
    # recomputation using the BN formula on the conv output.
    from compile.layers import act_fn, conv_op

    env = {"x": x}
    li = 0
    for node in mdef.nodes:
        if node["op"] == "conv":
            spec = node["spec"]
            p = params[spec.name]
            y = conv_op(env[node["src"]], p["w"], spec)
            if spec.bn:
                y = (y - p["mean"]) / jnp.sqrt(p["var"] + 1e-5) * p["gamma"] + p["beta"]
            else:
                y = y + p["b"]
            env[node["dst"]] = act_fn(y, spec.act)
            li += 1
        elif node["op"] == "save":
            env[node["dst"]] = env[node["src"]]
        elif node["op"] == "add":
            env[node["dst"]] = act_fn(env[node["src"]] + env[node["other"]], node["act"])
        elif node["op"] == "gap":
            env["x"] = jnp.mean(env["x"], axis=(1, 2))
    np.testing.assert_allclose(folded, env["x"], rtol=1e-4, atol=1e-4)


def test_forward_train_updates_bn_stats():
    mdef = build("regnett")
    params = init_params(mdef, seed=1)
    x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (8, 32, 32, 3)), jnp.float32)
    _, updates = forward_train(mdef, params, x)
    assert updates  # every BN layer reports new running stats
    for name, upd in updates.items():
        assert set(upd) == {"mean", "var"}


@pytest.mark.parametrize("name", list(ZOO))
def test_unique_layer_names(name):
    mdef = build(name)
    names = [s.name for s in mdef.convs]
    assert len(names) == len(set(names))


def test_coding_view_dims():
    mdef = build("mobilenetv2t")
    for spec in mdef.convs:
        n, m = spec.coding_view()
        assert n * m == spec.params
        assert m == spec.out_ch
