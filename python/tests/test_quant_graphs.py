"""L2 calibration-graph semantics: the step executables must actually
reduce reconstruction loss, the scan must equal K single steps, and the
activation fake-quant path must degrade gracefully with bits."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import quant
from compile.layers import ConvSpec
from compile.models import build
from compile.layers import fold_model, init_params


def small_conv_spec():
    return ConvSpec(name="t", kind="conv", in_ch=4, out_ch=8, ksize=3, act="none")


def make_problem(seed=0):
    rng = np.random.default_rng(seed)
    spec = small_conv_spec()
    w = jnp.asarray(rng.normal(0, 0.2, spec.wshape), jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (4, 8, 8, 4)), jnp.float32)
    y_ref = quant.make_layer_fwd(spec)(x, w)
    return spec, w, x, y_ref


def grid_params(w, bits=4):
    s = float(jnp.max(jnp.abs(w))) / (1 << (bits - 1))
    half = 1 << (bits - 1)
    return s, float(-half), float(half - 1)


def test_attention_step_reduces_loss():
    spec, w, x, y_ref = make_problem()
    s, lo, hi = grid_params(w)
    step = jax.jit(quant.make_attention_calib_step(spec))
    alpha = jnp.asarray(np.random.default_rng(1).normal(0, 0.5, w.shape), jnp.float32)
    m = jnp.zeros_like(w)
    v = jnp.zeros_like(w)
    losses = []
    for t in range(60):
        alpha, m, v, loss = step(w, x, y_ref, alpha, m, v, float(t), 0.05, 0.5,
                                 s, lo, hi)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.9, losses[:3] + losses[-3:]


def test_adaround_step_reduces_loss():
    spec, w, x, y_ref = make_problem(2)
    s, lo, hi = grid_params(w)
    step = jax.jit(quant.make_adaround_calib_step(spec))
    rng = np.random.default_rng(3)
    vv = jnp.asarray(rng.normal(0, 1, w.shape), jnp.float32)
    m = jnp.zeros_like(w)
    v = jnp.zeros_like(w)
    losses = []
    for t in range(60):
        vv, m, v, loss = step(w, x, y_ref, vv, m, v, float(t), 0.05, 20.0, 0.0,
                              s, lo, hi)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.9


def test_scan_equals_k_single_steps():
    spec, w, x, y_ref = make_problem(4)
    s, lo, hi = grid_params(w)
    k = 4
    step = jax.jit(quant.make_attention_calib_step(spec))
    scan = jax.jit(quant.make_attention_calib_scan(spec, k))
    rng = np.random.default_rng(5)
    alpha0 = jnp.asarray(rng.normal(0, 0.5, w.shape), jnp.float32)
    xs = jnp.stack([x] * k)
    ys = jnp.stack([y_ref] * k)
    a_scan, m_scan, v_scan, mean_loss = scan(
        w, xs, ys, alpha0, jnp.zeros_like(w), jnp.zeros_like(w), 0.0, 0.05,
        0.5, s, lo, hi
    )
    alpha, m, v = alpha0, jnp.zeros_like(w), jnp.zeros_like(w)
    losses = []
    for t in range(k):
        alpha, m, v, loss = step(w, x, y_ref, alpha, m, v, float(t), 0.05, 0.5,
                                 s, lo, hi)
        losses.append(float(loss))
    np.testing.assert_allclose(a_scan, alpha, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(mean_loss), np.mean(losses), rtol=1e-5)


def test_adaround_h_range():
    v = jnp.linspace(-10, 10, 101)
    h = quant.adaround_h(v)
    assert float(h.min()) == 0.0 and float(h.max()) == 1.0


def test_forward_actq_identity_at_high_bits():
    """Huge activation range ⇒ actq forward ≈ plain forward."""
    mdef = build("resnet18t")
    params = init_params(mdef, seed=0)
    ws, bs = fold_model(mdef, params)
    ws = [jnp.asarray(w) for w in ws]
    bs = [jnp.asarray(b) for b in bs]
    k = len(ws)
    x = jnp.asarray(np.random.default_rng(1).normal(0, 1, (2, 32, 32, 3)), jnp.float32)
    plain = quant.make_forward(mdef)(x, *ws, *bs)
    # Untrained activations reach O(100); cover [-1024, ~7400] with a
    # 1e-4 step so fake-quant is numerically the identity.
    scales = jnp.full((k,), 1e-4, jnp.float32)
    zeros = jnp.full((k,), -1024.0, jnp.float32)
    his = jnp.full((k,), 2.0**26, jnp.float32)
    fq = quant.make_forward_actq(mdef)(x, *ws, *bs, scales, zeros, his)
    np.testing.assert_allclose(plain, fq, rtol=1e-2, atol=1e-2)


def test_forward_actq_monotone_in_bits():
    """Lower activation bits must not beat higher bits on logit fidelity."""
    mdef = build("resnet18t")
    params = init_params(mdef, seed=2)
    ws, bs = fold_model(mdef, params)
    ws = [jnp.asarray(w) for w in ws]
    bs = [jnp.asarray(b) for b in bs]
    k = len(ws)
    x = jnp.asarray(np.random.default_rng(3).normal(0, 1, (4, 32, 32, 3)), jnp.float32)
    plain = quant.make_forward(mdef)(x, *ws, *bs)
    errs = []
    # fixed clip range wide enough for the untrained activations (~O(100));
    # only the grid step varies with bits, so error must grow as bits drop
    for bits in (8, 4, 2):
        hi = float(2**bits - 1)
        scales = jnp.full((k,), 1024.0 / hi, jnp.float32)
        zeros = jnp.full((k,), -512.0, jnp.float32)
        his = jnp.full((k,), hi, jnp.float32)
        out = quant.make_forward_actq(mdef)(x, *ws, *bs, scales, zeros, his)
        errs.append(float(jnp.mean((out - plain) ** 2)))
    assert errs[0] <= errs[1] <= errs[2], errs


def test_qat_step_shapes_and_loss_decrease():
    mdef = build("resnet18t")
    params = init_params(mdef, seed=4)
    ws, bs = fold_model(mdef, params)
    ws = [jnp.asarray(w) for w in ws]
    bs = [jnp.asarray(b) for b in bs]
    k = len(ws)
    mws = [jnp.zeros_like(w) for w in ws]
    mbs = [jnp.zeros_like(b) for b in bs]
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(0, 1, (8, 32, 32, 3)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 16, 8), jnp.int32)
    step = jax.jit(quant.make_qat_step(mdef))
    losses = []
    for _ in range(8):
        outs = step(x, y, *ws, *bs, *mws, *mbs, 0.05, 7.0, 15.0)
        ws = list(outs[:k])
        bs = list(outs[k : 2 * k])
        mws = list(outs[2 * k : 3 * k])
        mbs = list(outs[3 * k : 4 * k])
        losses.append(float(outs[-1]))
    assert losses[-1] < losses[0], losses
