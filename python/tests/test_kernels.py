"""L1 kernel correctness: Pallas (interpret) vs pure-jnp oracles.

Hypothesis sweeps shapes, scales, and hyperparameters; every kernel must
match its oracle to f32 tolerance. This is the core correctness signal for
the quantizer the whole stack executes.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import ref
from compile.kernels.attention_round import (
    attention_grad,
    attention_quant,
    fakequant,
)
from compile.kernels.gram import gram
from compile.kernels.qmatmul import qmatmul

hypothesis.settings.register_profile(
    "kernels", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("kernels")


def rand(rng, shape, scale=1.0):
    return jnp.asarray(rng.normal(0.0, scale, shape).astype(np.float32))


shapes = st.sampled_from(
    [(4,), (3, 5), (2, 3, 7), (1, 1, 1, 9), (65,), (128,), (257,), (8, 128),
     (3, 3, 16, 16), (1030,)]
)


@given(shape=shapes, s=st.sampled_from([0.01, 0.1, 0.5]),
       bits=st.sampled_from([2, 3, 4, 8]), seed=st.integers(0, 10))
def test_fakequant_matches_ref(shape, s, bits, seed):
    rng = np.random.default_rng(seed)
    w = rand(rng, shape)
    a = rand(rng, shape, 0.5)
    half = 1 << (bits - 1)
    lo, hi = float(-half), float(half - 1)
    out = fakequant(w, a, s, lo, hi)
    exp = ref.fakequant_ref(w, a, s, lo, hi)
    np.testing.assert_allclose(out, exp, rtol=0, atol=1e-6)


@given(shape=shapes, tau=st.sampled_from([0.0, 0.05, 0.5, 1.0]),
       seed=st.integers(0, 10))
def test_attention_grad_matches_ref(shape, tau, seed):
    rng = np.random.default_rng(seed + 100)
    g = rand(rng, shape)
    a = rand(rng, shape, 0.7)
    out = attention_grad(g, a, tau)
    exp = ref.attention_grad_ref(g, a, tau)
    np.testing.assert_allclose(out, exp, rtol=1e-5, atol=1e-6)


def test_attention_grad_sign_rule():
    """Eq. (6): for g>0 the gradient magnitude grows with α (already past
    the target cell), for g<0 it shrinks."""
    a = jnp.asarray([-2.0, 0.0, 2.0], jnp.float32)
    gp = attention_grad(jnp.ones(3), a, 0.5)
    assert gp[0] < gp[1] < gp[2]
    gn = attention_grad(-jnp.ones(3), a, 0.5)
    assert gn[0] < gn[1] < gn[2]  # g<0: -1*(0.5-0.5erf) increasing in α
    # symmetric at α=0: |dz/dα| = 0.5
    np.testing.assert_allclose(gp[1], 0.5, atol=1e-6)
    np.testing.assert_allclose(gn[1], -0.5, atol=1e-6)


@given(seed=st.integers(0, 5), tau=st.sampled_from([0.1, 0.5]))
def test_custom_vjp_routes_grad_to_alpha_only(seed, tau):
    rng = np.random.default_rng(seed)
    w = rand(rng, (6, 7))
    a = rand(rng, (6, 7), 0.4)

    def loss(w_, a_):
        return jnp.sum(attention_quant(w_, a_, 0.1, -8.0, 7.0, tau) ** 2)

    gw, ga = jax.grad(loss, argnums=(0, 1))(w, a)
    assert float(jnp.max(jnp.abs(gw))) == 0.0  # w is frozen in PTQ
    z = ref.fakequant_ref(w, a, 0.1, -8.0, 7.0)
    exp = ref.attention_grad_ref(2.0 * z * 0.1, a, tau)
    np.testing.assert_allclose(ga, exp, rtol=1e-5, atol=1e-6)


@given(m=st.sampled_from([1, 7, 50, 130]), k=st.sampled_from([3, 16, 70]),
       n=st.sampled_from([2, 33, 129]), seed=st.integers(0, 5))
def test_qmatmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed + 7)
    x = rand(rng, (m, k))
    w = rand(rng, (k, n))
    out = qmatmul(x, w, 0.05, 0.04, 0.0, 255.0, -8.0, 7.0)
    exp = ref.qmatmul_ref(x, w, 0.05, 0.04, 0.0, 255.0, -8.0, 7.0)
    np.testing.assert_allclose(out, exp, rtol=1e-5, atol=1e-4)


@given(m=st.sampled_from([2, 16, 100, 140]), n=st.sampled_from([3, 27, 300]),
       seed=st.integers(0, 5))
def test_gram_matches_ref(m, n, seed):
    rng = np.random.default_rng(seed + 13)
    w = rand(rng, (m, n))
    np.testing.assert_allclose(gram(w), ref.gram_ref(w), rtol=1e-5, atol=1e-4)


def test_fakequant_idempotent():
    """Quantizing an already-quantized tensor is the identity."""
    rng = np.random.default_rng(0)
    w = rand(rng, (33,))
    zero = jnp.zeros_like(w)
    q1 = fakequant(w, zero, 0.1, -8.0, 7.0)
    q2 = fakequant(q1, zero, 0.1, -8.0, 7.0)
    np.testing.assert_allclose(q1, q2, atol=1e-6)


def test_fakequant_output_on_grid():
    rng = np.random.default_rng(1)
    w = rand(rng, (101,))
    a = rand(rng, (101,), 0.3)
    s = 0.25
    out = np.asarray(fakequant(w, a, s, -8.0, 7.0))
    q = out / s
    np.testing.assert_allclose(q, np.round(q), atol=1e-5)
    assert q.min() >= -8.0 and q.max() <= 7.0


def test_coding_length_ref_monotone():
    rng = np.random.default_rng(2)
    w_small = jnp.asarray(rng.normal(0, 0.01, (16, 64)).astype(np.float32))
    w_big = jnp.asarray(rng.normal(0, 1.0, (16, 64)).astype(np.float32))
    assert ref.coding_length_ref(w_big, 1e-3) > ref.coding_length_ref(w_small, 1e-3)
