//! Host-only stub of the `xla` crate's PJRT surface.
//!
//! The real dependency (xla_extension 0.5.1 + PJRT CPU plugin) is not
//! available in the offline build, so this path crate implements the
//! exact API subset `attention_round::runtime` consumes:
//!
//! * host "uploads" and literal round-trips work for real (buffers hold
//!   host memory), so every host-side unit test runs unchanged;
//! * `HloModuleProto::from_text_file` / `PjRtLoadedExecutable::execute_b`
//!   return clean errors, so device-path integration tests self-skip the
//!   same way they do on a checkout without artifacts.
//!
//! Swapping the real crate back in is a one-line change in
//! `rust/Cargo.toml`; nothing in `src/` references stub-only items.

use std::fmt;

/// Error type mirroring `xla::Error`'s role: displayable, boxable.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn new(msg: impl Into<String>) -> Self {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    Pred,
}

/// Type-erased host storage (public only because it appears in the
/// [`NativeType`] trait surface).
#[doc(hidden)]
#[derive(Debug, Clone)]
pub enum Data {
    F32(Vec<f32>),
    S32(Vec<i32>),
}

impl Data {
    fn ty(&self) -> ElementType {
        match self {
            Data::F32(_) => ElementType::F32,
            Data::S32(_) => ElementType::S32,
        }
    }

    fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::S32(v) => v.len(),
        }
    }
}

/// Element types a host buffer / literal can carry.
pub trait NativeType: Copy {
    fn element_type() -> ElementType;
    fn to_data(vals: &[Self]) -> Data;
    fn from_data(data: &Data) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    fn element_type() -> ElementType {
        ElementType::F32
    }

    fn to_data(vals: &[Self]) -> Data {
        Data::F32(vals.to_vec())
    }

    fn from_data(data: &Data) -> Result<Vec<Self>> {
        match data {
            Data::F32(v) => Ok(v.clone()),
            other => Err(Error::new(format!(
                "literal holds {:?}, requested F32",
                other.ty()
            ))),
        }
    }
}

impl NativeType for i32 {
    fn element_type() -> ElementType {
        ElementType::S32
    }

    fn to_data(vals: &[Self]) -> Data {
        Data::S32(vals.to_vec())
    }

    fn from_data(data: &Data) -> Result<Vec<Self>> {
        match data {
            Data::S32(v) => Ok(v.clone()),
            other => Err(Error::new(format!(
                "literal holds {:?}, requested S32",
                other.ty()
            ))),
        }
    }
}

/// Array shape: dimensions + element type.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// A host literal: typed data + shape.
#[derive(Debug, Clone)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal {
            data: T::to_data(&[v]),
            dims: vec![],
        }
    }

    pub fn vec1<T: NativeType>(vals: &[T]) -> Literal {
        Literal {
            data: T::to_data(vals),
            dims: vec![vals.len() as i64],
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error::new(format!(
                "reshape {:?} wants {} elements, literal has {}",
                dims,
                n,
                self.data.len()
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape {
            dims: self.dims.clone(),
            ty: self.data.ty(),
        })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::from_data(&self.data)
    }

    /// Decompose a tuple literal. The stub never produces tuples (they
    /// only come out of device execution), so this always errors.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::new("stub literal is not a tuple"))
    }
}

/// Placeholder device handle (the CPU stub has exactly one).
#[derive(Debug, Clone, Copy)]
pub struct PjRtDevice;

/// A "device" buffer — host memory in the stub.
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    lit: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.lit.clone())
    }
}

/// Parsed HLO module. `from_text_file` always errors in the stub: there
/// is no compiler behind it, and callers already treat load failures as
/// "artifacts unavailable".
#[derive(Debug, Clone)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(Error::new(format!(
            "PJRT unavailable (vendored xla stub): cannot parse {path}"
        )))
    }
}

#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Compiled executable. Unreachable in practice (compilation errors
/// first), but the type must exist and execute must typecheck.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new("PJRT unavailable (vendored xla stub)"))
    }
}

/// The PJRT client. Uploads work against host memory; compile errors.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "cpu (vendored stub)".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::new("PJRT unavailable (vendored xla stub)"))
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        let n: usize = dims.iter().product();
        if n != data.len() {
            return Err(Error::new(format!(
                "buffer shape {:?} wants {} elements, got {}",
                dims,
                n,
                data.len()
            )));
        }
        Ok(PjRtBuffer {
            lit: Literal {
                data: T::to_data(data),
                dims: dims.iter().map(|&d| d as i64).collect(),
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 2]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn client_upload_and_readback() {
        let c = PjRtClient::cpu().unwrap();
        assert!(c.platform_name().contains("cpu"));
        let buf = c
            .buffer_from_host_buffer(&[1i32, 2, 3], &[3], None)
            .unwrap();
        assert_eq!(buf.to_literal_sync().unwrap().to_vec::<i32>().unwrap(), vec![1, 2, 3]);
        assert!(c.buffer_from_host_buffer(&[1.0f32], &[2], None).is_err());
    }

    #[test]
    fn device_paths_error_cleanly() {
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        assert!(PjRtClient::cpu().unwrap().compile(&XlaComputation).is_err());
        assert!(PjRtLoadedExecutable.execute_b(&[]).is_err());
        assert!(Literal::scalar(1.0f32).to_tuple().is_err());
    }
}
