//! Vendored minimal implementation of the `log` crate facade.
//!
//! The offline build has no crates.io access, so this path crate provides
//! the exact subset of the facade the workspace uses: the `Level` /
//! `LevelFilter` enums, the `Log` trait with `Metadata` / `Record`,
//! `set_logger` / `set_max_level` / `max_level`, and the five level
//! macros. Semantics match the upstream crate for that subset; anything
//! upstream offers beyond it is intentionally absent.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Log verbosity levels, most severe first (matches upstream ordering:
/// `Error < Warn < ... < Trace`).
#[repr(usize)]
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

/// A level filter: like [`Level`] plus `Off`.
#[repr(usize)]
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl PartialEq<Level> for LevelFilter {
    fn eq(&self, other: &Level) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<Level> for LevelFilter {
    fn partial_cmp(&self, other: &Level) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Metadata about a log record: level + target module path.
#[derive(Debug, Clone, Copy)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log event, carrying preformatted arguments.
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// The logging backend contract.
pub trait Log: Sync + Send {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);
static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();

/// Error returned when a logger is installed twice.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

pub fn set_max_level(level: LevelFilter) {
    MAX_LEVEL.store(level as usize, Ordering::Relaxed);
}

pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        0 => LevelFilter::Off,
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        _ => LevelFilter::Trace,
    }
}

pub fn logger() -> Option<&'static dyn Log> {
    LOGGER.get().copied()
}

/// Macro plumbing — public because the macros expand in caller crates.
#[doc(hidden)]
pub fn __private_api_log(args: fmt::Arguments, level: Level, target: &str) {
    if (level as usize) > MAX_LEVEL.load(Ordering::Relaxed) {
        return;
    }
    if let Some(l) = LOGGER.get() {
        let record = Record {
            metadata: Metadata { level, target },
            args,
        };
        if l.enabled(&record.metadata) {
            l.log(&record);
        }
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__private_api_log(format_args!($($arg)+), $lvl, module_path!())
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_orders_against_filter() {
        assert!(Level::Error <= LevelFilter::Info);
        assert!(Level::Info <= LevelFilter::Info);
        assert!(!(Level::Debug <= LevelFilter::Info));
        assert!(Level::Trace > LevelFilter::Off);
    }

    #[test]
    fn max_level_roundtrip() {
        set_max_level(LevelFilter::Debug);
        assert_eq!(max_level(), LevelFilter::Debug);
        set_max_level(LevelFilter::Off);
        assert_eq!(max_level(), LevelFilter::Off);
    }

    #[test]
    fn macros_are_safe_without_logger() {
        // No logger installed in this test binary: must be a silent no-op.
        crate::info!("hello {}", 1);
        crate::debug!("world");
    }
}
